"""Pallas TPU kernel: fully-connected forward with a reused bit-packed tile.

TPU adaptation of the paper's Triton inference kernel (Section 5.2). The
weight never exists densely: HBM holds one bit-packed tile
``packed (r, K/32) int32`` (r = n_out / p unique weight rows). Per grid step
the kernel pulls an (bm, bk) activation block and a (br, bk/32) packed block
into VMEM, unpacks the bits to ±1 in-register (shift/and on the VPU), and
feeds the MXU:

    u = x @ T^T          -- p-fold fewer FLOPs than the dense layer
    y = kron(alpha, u)   -- broadcast-scale applied by the wrapper (ops.py)

Weight HBM traffic is 32*p smaller than fp32 (p smaller than 1-bit BWNN);
the VMEM working set is (bm*bk + br*bk/32 + bm*br) elements — block sizes
default to MXU-aligned (128) multiples and are sweepable for the perf loop.

Grid: (M/bm, r/br, K/bk), k innermost (sequential accumulation); m/r are
parallel. The f32 accumulator lives in VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

LANE_BITS = 32


def _unpack_block(words: jax.Array, br: int, bk: int, dtype) -> jax.Array:
    """(br, bk/32) int32 words -> (br, bk) ±1 values of ``dtype``.

    Column c of the output reads bit (c % 32) of word (c // 32): broadcast
    each word over 32 lanes, shift by the lane's bit index, mask, map to ±1.
    """
    nw = bk // LANE_BITS
    u32 = words.astype(jnp.uint32)
    rep = jnp.broadcast_to(u32[:, :, None], (br, nw, LANE_BITS)).reshape(br, bk)
    shift = jax.lax.broadcasted_iota(jnp.uint32, (br, bk), 1) % LANE_BITS
    bits = (rep >> shift) & jnp.uint32(1)
    return (bits.astype(jnp.int8) * 2 - 1).astype(dtype)


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int, compute_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    br = w_ref.shape[0]
    w = _unpack_block(w_ref[...], br, bk, compute_dtype)
    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_matmul_unique(
    x: jax.Array,
    packed: jax.Array,
    *,
    r: int,
    block_m: int = 128,
    block_r: int = 128,
    block_k: int = 512,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """u = x @ T^T for a row-packed tile.

    x: (M, K). packed: (r, K/32) int32 (row-major bit order, see
    repro.core.packing). Returns (M, r) in ``out_dtype``.

    Shapes must be pre-padded to block multiples (ops.py handles padding).
    """
    m, k = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert k % LANE_BITS == 0, "K must be a multiple of 32 (packed lanes)"
    assert packed.shape == (r, k // LANE_BITS), (packed.shape, (r, k // LANE_BITS))
    block_m = min(block_m, m)
    block_r = min(block_r, r)
    block_k = min(block_k, k)
    assert m % block_m == 0 and r % block_r == 0 and k % block_k == 0
    assert block_k % LANE_BITS == 0
    nk = k // block_k
    compute_dtype = x.dtype if x.dtype in (jnp.bfloat16, jnp.float32) else jnp.float32

    kernel = functools.partial(_matmul_kernel, nk=nk, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, r // block_r, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ri, ki: (mi, ki)),
            pl.BlockSpec(
                (block_r, block_k // LANE_BITS), lambda mi, ri, ki: (ri, ki)
            ),
        ],
        out_specs=pl.BlockSpec((block_m, block_r), lambda mi, ri, ki: (mi, ri)),
        out_shape=jax.ShapeDtypeStruct((m, r), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_r), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed)

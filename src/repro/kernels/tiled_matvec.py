"""Pallas TPU kernel: decode-time tiled mat*vec* with a reused packed tile.

Small-m specialization of ``tiled_matmul_unique``. A continuous-batching
decode tick is an ``(n_slots, 1)`` batch — at the matmul kernel's default
``block_m=128`` the activation block is ~97% zero padding for the default
4 slots, and every MXU pass wastes the m dimension on rows that do not
exist. Here the whole sublane-rounded batch IS the m block (no m grid
axis, no m padding beyond the hardware sublane), and the freed VMEM goes
into wider ``block_r`` / ``block_k`` so each sequential k step amortizes
the bit-unpack (the dominant cost at small m — the kernel is
unpack-bound, not MXU-bound) over more output columns.

Grid: (r/br, K/bk), k innermost (sequential accumulation), r parallel.
VMEM per step: m·bk activations + br·bk/32 packed words + br·bk unpacked
weights + m·br f32 accumulator — at the decode defaults (m<=32, br=256,
bk=1024) ~1.3 MB, far under the ~16 MB/core budget.

Dispatch lives in ``ops.tiled_dense_infer``: any matmul with
m <= MATVEC_MAX_M (after flattening lead dims; per-shard m under the
tensor-parallel shard_map wrapper) routes here instead of the matmul
kernel. Oracle: ``kernels.ref.tiled_matvec_unique_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.tiled_matmul import LANE_BITS, _unpack_block

# Dispatch threshold: batches at or under this m take the decode path.
# 32 covers any realistic slot count while staying well inside the regime
# where the matmul kernel's 128-row m blocks are mostly padding.
MATVEC_MAX_M = 32
# Decode-tuned blocking: wider than the matmul defaults (128, 512) —
# with m tiny the accumulator and activation blocks are nearly free, so
# the unpack-dominant regime wants bigger weight blocks per grid step.
DECODE_BLOCK_R = 256
DECODE_BLOCK_K = 1024


# Smallest second-to-last dim a TPU tile supports, per dtype: 4-byte
# dtypes tile at (8, 128), 2-byte at (16, 128), 1-byte at (32, 128).
# An EXPLICIT table — the old `8 if f32 else 16` silently mis-rounded
# int8 (which needs 32 sublanes) and any other non-f32 dtype.
_SUBLANE_MULT = {
    "float32": 8, "int32": 8, "uint32": 8,
    "bfloat16": 16, "float16": 16, "int16": 16, "uint16": 16,
    "int8": 32, "uint8": 32, "float8_e4m3fn": 32, "float8_e5m2": 32,
}


def sublane_rounded(m: int, dtype) -> int:
    """Round a decode batch up to the dtype's TPU sublane multiple.

    Raises a loud ValueError for dtypes without an entry rather than
    guessing — a wrong sublane multiple produces a mis-shaped m block
    that Mosaic rejects (or worse, pads wastefully) far from here.
    """
    name = jnp.dtype(dtype).name
    mult = _SUBLANE_MULT.get(name)
    if mult is None:
        raise ValueError(
            f"no TPU sublane rule for dtype {name!r} — known dtypes: "
            f"{sorted(_SUBLANE_MULT)}. Add an explicit entry to "
            f"_SUBLANE_MULT (tiled_matvec.py) for the new dtype's tile "
            f"shape instead of letting callers guess."
        )
    return -(-m // mult) * mult


def _matvec_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int, compute_dtype):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    br = w_ref.shape[0]
    w = _unpack_block(w_ref[...], br, bk, compute_dtype)
    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_matvec_unique(
    x: jax.Array,
    packed: jax.Array,
    *,
    r: int,
    block_r: int = DECODE_BLOCK_R,
    block_k: int = DECODE_BLOCK_K,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """u = x @ T^T for a row-packed tile at decode-sized m.

    x: (M, K) with M sublane-rounded (ops.py pads); packed: (r, K/32)
    int32. Returns (M, r) in ``out_dtype``. M is one block — there is no
    m grid axis; shapes must be pre-padded to block multiples on r/K.
    """
    m, k = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert k % LANE_BITS == 0, "K must be a multiple of 32 (packed lanes)"
    assert packed.shape == (r, k // LANE_BITS), (packed.shape, (r, k // LANE_BITS))
    block_r = min(block_r, r)
    block_k = min(block_k, k)
    assert r % block_r == 0 and k % block_k == 0
    assert block_k % LANE_BITS == 0
    nk = k // block_k
    compute_dtype = x.dtype if x.dtype in (jnp.bfloat16, jnp.float32) else jnp.float32

    kernel = functools.partial(_matvec_kernel, nk=nk, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=(r // block_r, nk),
        in_specs=[
            pl.BlockSpec((m, block_k), lambda ri, ki: (0, ki)),
            pl.BlockSpec(
                (block_r, block_k // LANE_BITS), lambda ri, ki: (ri, ki)
            ),
        ],
        out_specs=pl.BlockSpec((m, block_r), lambda ri, ki: (0, ri)),
        out_shape=jax.ShapeDtypeStruct((m, r), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, block_r), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed)

"""TBN Pallas TPU kernels (validated in interpret mode on CPU)."""
from repro.kernels.ops import (
    FlatTileLayoutError,
    resolve_conv_padding,
    tbn_dense_train,
    tile_construct,
    tiled_conv_infer,
    tiled_dense_infer,
)
from repro.kernels.tile_construct import tile_construct_pallas
from repro.kernels.tiled_conv import tiled_conv_unique
from repro.kernels.tiled_matmul import tiled_matmul_unique
from repro.kernels.tiled_matvec import MATVEC_MAX_M, tiled_matvec_unique
from repro.kernels.tiled_xnor import (
    COMPUTE_PATHS,
    quantize_int8,
    quantize_sign,
    tiled_int8_matvec_unique,
    tiled_xnor_matvec_unique,
)

__all__ = [
    "FlatTileLayoutError",
    "resolve_conv_padding",
    "tbn_dense_train",
    "tile_construct",
    "tiled_conv_infer",
    "tiled_dense_infer",
    "tile_construct_pallas",
    "tiled_conv_unique",
    "tiled_matmul_unique",
    "tiled_matvec_unique",
    "MATVEC_MAX_M",
    "COMPUTE_PATHS",
    "quantize_int8",
    "quantize_sign",
    "tiled_int8_matvec_unique",
    "tiled_xnor_matvec_unique",
]

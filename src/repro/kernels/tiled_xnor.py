"""Pallas TPU kernels: integer-domain decode matvec on packed tile words.

The float kernels (tiled_matmul / tiled_matvec) ship sub-bit *weights*
but still unpack every packed word to ±1 floats and burn MXU float MACs
— at decode sizes the matvec kernel is unpack-bound, not MXU-bound. The
BNN lineage ("Bitwise Neural Networks", Kim & Smaragdis 2016; XNOR-Net)
gets its speed by never leaving the integer domain: quantize the
activations too and accumulate directly against the packed
``(r, ceil(n_in/32))`` tile words. Two compute paths live here, both
decode-oriented (m <= MATVEC_MAX_M after flattening lead dims — the
``(n_slots, 1)`` tick batch):

* ``xnor`` — sign-binarize activations, bit-pack them with the SAME
  little-endian word layout as the weights (repro.core.packing), and
  compute the integer dot product per output as

      acc[i, j] = n_in - 2 * sum_w popcount(xq[i, w] XOR wq[j, w])

  No unpack, no MAC of any kind: each packed word contributes one
  32-lane XOR + one SWAR popcount on the VPU. Padding needs no masks —
  pad bits of BOTH operands pack to 0, so their XOR is 0 and popcount
  ignores them (disagreements can only occur in valid bits).

* ``int8`` — the accuracy-preserving middle step: per-row symmetric
  int8 activations against {0, 1} weight bits through the MXU's integer
  ``dot_general`` (preferred_element_type=int32), folded to the ±1 dot
  with ``acc = 2 * (q @ bits^T) - rowsum(q)``. The weight words are
  expanded to a 0/1 *select mask* (shift/and, one byte per bit) — never
  to ±1 floats — and every MAC is int8 x int8 -> int32.

Both kernels return the raw int32 accumulator; the wrapper (ops.py)
applies the activation scale ``u = scale * acc`` and the usual alpha
replica broadcast. The accumulators are BIT-IDENTICAL to the pure-JAX
oracles (``kernels.ref.tiled_xnor_matvec_ref`` — which uses
``jax.lax.population_count``, an implementation independent of the SWAR
popcount here — and ``tiled_int8_matvec_ref``), so the parity wall
asserts exact integer equality, not allclose.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.packing import pack_bits
from repro.kernels.tiled_matvec import sublane_rounded

LANE_BITS = 32
# Dispatchable compute paths for the tiled dense serve apply. "float" is
# the byte-parity reference (the existing unpack + MXU float kernels);
# the integer paths engage only at decode m (ops.py falls back to float
# for prefill-sized batches).
COMPUTE_PATHS = ("float", "int8", "xnor")

# Decode-tuned blocking. The xnor kernel blocks over packed WORDS (one
# word = 32 weight bits): 32 words = 1024 bits per sequential step, same
# k footprint as the float matvec's DECODE_BLOCK_K.
XNOR_BLOCK_R = 256
XNOR_BLOCK_W = 32
INT8_BLOCK_R = 256
INT8_BLOCK_K = 1024


# --------------------------------------------------------------------------
# Activation quantization (pure jnp — shared by wrapper, oracle and tests)
# --------------------------------------------------------------------------
def quantize_sign(x: jax.Array, n_in: int) -> Tuple[jax.Array, jax.Array]:
    """Sign-binarize activation rows for the pure-XNOR path.

    x: (m, k >= n_in) — columns past n_in are ignored. Returns
    (packed (m, ceil(n_in/32)) int32, scale (m, 1) f32) where
    ``scale = mean|x_row|`` (XNOR-Net's per-row activation scale) and
    bit j of word w encodes ``sign(x[:, w*32+j]) > 0`` in the same
    little-endian layout as the weight tiles, pad bits 0.
    """
    xv = x[:, :n_in].astype(jnp.float32)
    scale = jnp.mean(jnp.abs(xv), axis=1, keepdims=True)
    return pack_bits(xv > 0), scale


def quantize_int8(x: jax.Array, n_in: int) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization (the accuracy-preserving step).

    x: (m, k >= n_in). Returns (q (m, n_in) int8 in [-127, 127],
    scale (m, 1) f32) with ``x ~= q * scale``; an all-zero row gets
    scale 1 so the dequant stays finite.
    """
    xv = x[:, :n_in].astype(jnp.float32)
    amax = jnp.max(jnp.abs(xv), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xv / scale), -127, 127).astype(jnp.int8)
    return q, scale


def popcount32(v: jax.Array) -> jax.Array:
    """SWAR popcount of each int32/uint32 lane -> int32 counts.

    Shift/and/add only (no multiply, no lookup) so it lowers to plain
    VPU vector ops inside a Pallas kernel; the oracle deliberately uses
    ``jax.lax.population_count`` instead so the two implementations
    check each other.
    """
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v + (v >> 8) + (v >> 16) + (v >> 24)) & jnp.uint32(0x3F)
    return v.astype(jnp.int32)


# --------------------------------------------------------------------------
# XNOR + popcount kernel (packed words x packed words)
# --------------------------------------------------------------------------
def _xnor_kernel(x_ref, w_ref, o_ref, acc_ref, *, nw_steps: int, n_in: int):
    """One (r block, word block) step: acc += popcount(x XOR w) per word.

    x_ref (bm, bw) int32 packed activation words; w_ref (bw, br) int32
    packed weight words TRANSPOSED so each word index is a row — the
    (bm, 1) x (1, br) XOR broadcast stays 2D for the VPU. The word loop
    is a static unroll (bw is a compile-time block size).
    """
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bw = x_ref.shape[1]
    pop = acc_ref[...]
    for j in range(bw):
        xw = x_ref[:, j : j + 1]            # (bm, 1) int32
        ww = w_ref[j : j + 1, :]            # (1, br) int32
        pop += popcount32(jnp.bitwise_xor(xw, ww))
    acc_ref[...] = pop

    @pl.when(ki == nw_steps - 1)
    def _store():
        # integer ±1 dot: matches = n - pop, acc = matches - pop
        o_ref[...] = jnp.int32(n_in) - 2 * acc_ref[...]


def tiled_xnor_matvec_unique(
    packed_x: jax.Array,
    packed_rows: jax.Array,
    *,
    n_in: int,
    block_r: int = XNOR_BLOCK_R,
    block_w: int = XNOR_BLOCK_W,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """acc = sign(x) . T^T in the INTEGER domain, from packed words only.

    packed_x: (M, W) int32 sign-packed activations (quantize_sign);
    packed_rows: (r, W) int32 row-packed tile. Both pre-padded: M to the
    int32 sublane multiple, W to block_w multiples, r to block_r
    multiples — pad words are 0 on both sides so they cannot contribute
    (XOR of equal pad bits is 0). Returns (M, r) int32, the exact ±1 dot
    over the first n_in bit positions.
    """
    m, w_words = packed_x.shape
    r = packed_rows.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert packed_rows.shape[1] == w_words, (packed_rows.shape, w_words)
    block_r = min(block_r, r)
    block_w = min(block_w, w_words)
    assert r % block_r == 0 and w_words % block_w == 0
    nw_steps = w_words // block_w
    # word-index-major layout so the kernel's per-word broadcast is 2D
    wq_t = packed_rows.T  # (W, r)

    kernel = functools.partial(_xnor_kernel, nw_steps=nw_steps, n_in=n_in)
    return pl.pallas_call(
        kernel,
        grid=(r // block_r, nw_steps),
        in_specs=[
            pl.BlockSpec((m, block_w), lambda ri, ki: (0, ki)),
            pl.BlockSpec((block_w, block_r), lambda ri, ki: (ki, ri)),
        ],
        out_specs=pl.BlockSpec((m, block_r), lambda ri, ki: (0, ri)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.int32),
        scratch_shapes=[pltpu.VMEM((m, block_r), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(packed_x, wq_t)


# --------------------------------------------------------------------------
# int8 x binary kernel (integer MXU dot against a 0/1 select mask)
# --------------------------------------------------------------------------
def _unpack_bits01(words: jax.Array, br: int, bk: int) -> jax.Array:
    """(br, bk/32) int32 words -> (br, bk) {0, 1} int8 select mask.

    Same shift/and expansion as the float kernels' ``_unpack_block`` but
    the bits stay a 0/1 integer mask — the ±1 map happens arithmetically
    in the accumulator fold, never as a float."""
    nw = bk // LANE_BITS
    u32 = words.astype(jnp.uint32)
    rep = jnp.broadcast_to(u32[:, :, None], (br, nw, LANE_BITS)).reshape(br, bk)
    shift = jax.lax.broadcasted_iota(jnp.uint32, (br, bk), 1) % LANE_BITS
    return ((rep >> shift) & jnp.uint32(1)).astype(jnp.int8)


def _int8_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    br = w_ref.shape[0]
    bits = _unpack_bits01(w_ref[...], br, bk)
    q = x_ref[...]
    # s1 = q @ bits^T over the +1 positions; the ±1 dot is 2*s1 - sum(q)
    # (pad columns hold q = 0, so both terms ignore them)
    s1 = jax.lax.dot_general(
        q, bits, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )
    rowsum = jnp.sum(q.astype(jnp.int32), axis=1, keepdims=True)
    acc_ref[...] += 2 * s1 - rowsum

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...]


def tiled_int8_matvec_unique(
    q: jax.Array,
    packed_rows: jax.Array,
    *,
    r: int,
    block_r: int = INT8_BLOCK_R,
    block_k: int = INT8_BLOCK_K,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """acc = q . T^T with int8 activations and binary weights, int32 MACs.

    q: (M, K) int8, M pre-padded to the int8 sublane multiple (32) and K
    to block_k multiples with ZERO pad columns; packed_rows:
    (r, K/32) int32. Returns (M, r) int32 — the exact integer dot of q
    against the ±1 rows.
    """
    m, k = q.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert q.dtype == jnp.int8, q.dtype
    assert k % LANE_BITS == 0, "K must be a multiple of 32 (packed lanes)"
    assert packed_rows.shape == (r, k // LANE_BITS), (
        packed_rows.shape, (r, k // LANE_BITS))
    block_r = min(block_r, r)
    block_k = min(block_k, k)
    assert r % block_r == 0 and k % block_k == 0
    assert block_k % LANE_BITS == 0
    nk = k // block_k

    kernel = functools.partial(_int8_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(r // block_r, nk),
        in_specs=[
            pl.BlockSpec((m, block_k), lambda ri, ki: (0, ki)),
            pl.BlockSpec(
                (block_r, block_k // LANE_BITS), lambda ri, ki: (ri, ki)
            ),
        ],
        out_specs=pl.BlockSpec((m, block_r), lambda ri, ki: (0, ri)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.int32),
        scratch_shapes=[pltpu.VMEM((m, block_r), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, packed_rows)


# --------------------------------------------------------------------------
# Structured (pure-jnp) integer paths — the non-Pallas backends
# --------------------------------------------------------------------------
def xnor_matvec_words(
    packed_x: jax.Array, packed_rows: jax.Array, *, n_in: int
) -> jax.Array:
    """Pure-jnp twin of the xnor kernel (SWAR popcount, same word math).

    This is what ``ops.tiled_dense_infer`` runs with use_pallas=False —
    CPU/GPU serving stays in the packed-word domain too (32x fewer loads
    than the unpack + float einsum reference). Bit-identical to the
    kernel AND to the independent ``ref.tiled_xnor_matvec_ref`` oracle.
    """
    xo = jnp.bitwise_xor(packed_x[:, None, :], packed_rows[None, :, :])
    return jnp.int32(n_in) - 2 * popcount32(xo).sum(axis=-1)


def int8_matvec_packed(
    q: jax.Array, packed_rows: jax.Array, *, n_in: int
) -> jax.Array:
    """Pure-jnp twin of the int8 kernel: 0/1 mask + integer dot."""
    words = packed_rows.shape[1]
    r = packed_rows.shape[0]
    bits = _unpack_bits01(packed_rows, r, words * LANE_BITS)[:, :n_in]
    s1 = jax.lax.dot_general(
        q[:, :n_in], bits, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return 2 * s1 - jnp.sum(q[:, :n_in].astype(jnp.int32), axis=1,
                            keepdims=True)


def int_sublane_dtype(compute_path: str):
    """The activation dtype whose sublane rule pads m for each path."""
    return jnp.int32 if compute_path == "xnor" else jnp.int8


__all__ = [
    "COMPUTE_PATHS",
    "XNOR_BLOCK_R",
    "XNOR_BLOCK_W",
    "INT8_BLOCK_R",
    "INT8_BLOCK_K",
    "quantize_sign",
    "quantize_int8",
    "popcount32",
    "tiled_xnor_matvec_unique",
    "tiled_int8_matvec_unique",
    "xnor_matvec_words",
    "int8_matvec_packed",
    "int_sublane_dtype",
    "sublane_rounded",
]

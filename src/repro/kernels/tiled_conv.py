"""Pallas TPU kernel: fused im2col conv forward with a reused bit-packed tile.

Conv analogue of ``tiled_matmul`` (DESIGN.md §4): the dense OIHW conv weight
never exists — HBM holds one bit-packed tile in "conv layout",
``packed (kh*kw, r, c_in/32) int32`` (r = c_out / p unique filters), and the
kernel contracts the conv as a sum over kernel positions of strided 1x1
matmuls against the unpacked tile cross-section:

    u[n, oh, ow, :] = sum_{i,j} x[n, oh*sh + i, ow*sw + j, :] @ T[i,j]^T
    y = kron(alpha, u)   -- broadcast over the p tile replicas (ops.py)

Patch extraction (im2col) is fused: per grid step the kernel pulls ONE
padded input row (1, Wp, C) and one packed (br, C/32) cross-section into
VMEM, gathers the stride-sw patch block in-register (dynamic slice at
column j, then a (ow, sw, C) subsample), unpacks the bits to ±1 on the VPU,
and feeds the MXU. Neither the im2col matrix nor the dense weight is ever
materialized in HBM — weight traffic is 32*p smaller than fp32.

Grid: (N*OH, r/br, kh*kw); the kernel-position axis is innermost and
sequential (accumulates into VMEM scratch), the row and filter axes are
parallel. VMEM working set per step: Wp*C + br*C/32 + 2*OW*br elements.
The wrapper (ops.tiled_conv_infer) handles SAME/VALID padding, channel
padding to whole 32-bit lanes, and filter padding to br multiples.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.tiled_matmul import _unpack_block

LANE_BITS = 32


def _conv_kernel(
    x_ref, w_ref, o_ref, acc_ref, *, kw: int, sw: int, ow: int, nk: int,
    compute_dtype,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = x_ref.shape[2]
    br = w_ref.shape[1]
    j = ki % kw
    # Fused patch gather: slice the row at column offset j, then keep every
    # sw-th pixel — the (ow, c) im2col block for kernel position (i, j).
    row = pl.load(x_ref, (pl.ds(0, 1), pl.ds(j, ow * sw), slice(None)))
    patch = row.reshape(ow, sw, c)[:, 0, :].astype(compute_dtype)  # (ow, c)
    t = _unpack_block(w_ref[0], br, c, compute_dtype)  # (br, c) in ±1
    acc_ref[...] += jax.lax.dot_general(
        patch, t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def tiled_conv_unique(
    x: jax.Array,
    packed: jax.Array,
    *,
    kernel: Tuple[int, int],
    stride: Tuple[int, int],
    out_hw: Tuple[int, int],
    block_r: int = 128,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """u[n,oh,ow,:] = patches(x) @ T^T for a conv-layout packed tile.

    x: (N, Hp, Wp, C) — already spatially padded so that every read is in
    bounds: Hp >= (OH-1)*sh + kh and Wp >= (kw-1) + OW*sw. C must be a
    multiple of 32. packed: (kh*kw, r, C/32) int32 (see
    repro.core.packing.pack_conv_tile); block_r must divide r (ops.py pads).
    Returns u (N, OH, OW, r) in ``out_dtype``.
    """
    n, hp, wp, c = x.shape
    kh, kw = kernel
    sh, sw = stride
    oh, ow = out_hw
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert c % LANE_BITS == 0, "C must be a multiple of 32 (packed lanes)"
    nk = kh * kw
    r = packed.shape[1]
    assert packed.shape == (nk, r, c // LANE_BITS), packed.shape
    assert r % block_r == 0, (r, block_r)  # caller clamps/pads (ops.py)
    assert hp >= (oh - 1) * sh + kh, (hp, oh, sh, kh)
    assert wp >= (kw - 1) + ow * sw, (wp, ow, sw, kw)

    xrows = x.reshape(n * hp, wp, c)

    def x_index(mi, ri, ki):
        # input row for output row block mi=(n, oh) at kernel row i=ki//kw
        return ((mi // oh) * hp + (mi % oh) * sh + ki // kw, 0, 0)

    kernel_fn = functools.partial(
        _conv_kernel, kw=kw, sw=sw, ow=ow, nk=nk,
        compute_dtype=(x.dtype if x.dtype in (jnp.bfloat16, jnp.float32)
                       else jnp.float32),
    )
    u = pl.pallas_call(
        kernel_fn,
        grid=(n * oh, r // block_r, nk),
        in_specs=[
            pl.BlockSpec((1, wp, c), x_index),
            pl.BlockSpec(
                (1, block_r, c // LANE_BITS), lambda mi, ri, ki: (ki, ri, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, ow, block_r), lambda mi, ri, ki: (mi, 0, ri)),
        out_shape=jax.ShapeDtypeStruct((n * oh, ow, r), out_dtype),
        scratch_shapes=[pltpu.VMEM((ow, block_r), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xrows, packed)
    return u.reshape(n, oh, ow, r)

"""Pure-jnp oracles for the TBN Pallas kernels.

These are the ground truth the kernels are validated against (allclose over
shape/dtype sweeps in tests/test_kernels_*.py) and the math the SPMD dry-run
lowers (the dry-run targets the host platform where Pallas TPU kernels
cannot compile — identical FLOPs/bytes, see DESIGN.md §7.2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import pack_bits, unpack_bits, unpack_conv_tile
from repro.core.tiling import (
    TileSpec,
    expand_alpha,
    plan_conv_tiling,
)


def tile_construct_ref(
    w2d: jax.Array, a2d: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """(p, q) master weight -> (packed tile int32 (ceil(q/32),), alpha (p,)).

    alpha here is always per-tile (Eq. 9); Eq. 7's layer alpha is its mean —
    the wrapper reduces when alpha_mode == "layer".
    """
    p, q = w2d.shape
    s = w2d.sum(axis=0)
    t = jnp.where(s > 0, 1.0, -1.0)
    src = w2d if a2d is None else a2d
    alpha = jnp.mean(jnp.abs(src), axis=1)
    return pack_bits(t), alpha.astype(jnp.float32)


def tiled_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    *,
    n_out: int,
    p: int,
) -> jax.Array:
    """Dense ground truth: y = x @ W_hat^T with W_hat fully materialized.

    x: (M, K); packed: int32 (ceil(q/32),) with q = n_out*K/p; alpha: (p,) or
    (1,). Returns (M, n_out) in float32.
    """
    m, k = x.shape
    q = n_out * k // p
    t = unpack_bits(packed, q, dtype=jnp.float32)
    b = jnp.broadcast_to(t[None, :], (p, q)).reshape(n_out, k)
    if alpha.shape[0] == 1:
        a_col = jnp.broadcast_to(alpha.reshape(1, 1), (p, q))
    else:
        a_col = jnp.broadcast_to(alpha[:, None], (p, q))
    bhat = b * a_col.reshape(n_out, k)
    return (x.astype(jnp.float32) @ bhat.T).astype(jnp.float32)


def tiled_matmul_unique_ref(
    x: jax.Array, packed: jax.Array, *, r: int
) -> jax.Array:
    """Oracle of the kernel's inner product only: u = x @ T^T (M, r)."""
    m, k = x.shape
    t = unpack_bits(packed, r * k, dtype=jnp.float32).reshape(r, k)
    return x.astype(jnp.float32) @ t.T


def tiled_matvec_unique_ref(
    x: jax.Array, packed_rows: jax.Array, *, n_in: int
) -> jax.Array:
    """Oracle for the decode matvec: u = x @ T^T from a ROW-packed tile.

    x (M, K>=n_in — pad columns beyond n_in must be zero); packed_rows
    (r, ceil(n_in/32)) int32, one word-padded packed row per unique weight
    row (the shipped serve form). Returns (M, r) float32. Same math as
    ``tiled_matmul_unique_ref`` up to the row-major vs row-packed layout.
    """
    t = unpack_bits(packed_rows, n_in, dtype=jnp.float32)  # (r, n_in)
    return x[:, :n_in].astype(jnp.float32) @ t.T


def tiled_xnor_matvec_ref(
    packed_x: jax.Array, packed_rows: jax.Array, *, n_in: int
) -> jax.Array:
    """Oracle for the XNOR decode matvec — INTEGER-exact ground truth.

    packed_x (m, W) int32 sign-packed activation words (pad bits 0);
    packed_rows (r, W) int32 row-packed tile words (pad bits 0). Returns
    the (m, r) int32 ±1 dot over the first n_in bit positions:
    ``n_in - 2 * popcount(x XOR w)`` — pad bits of both operands are 0,
    so their XOR never contributes. Deliberately uses
    ``jax.lax.population_count`` so the kernel's SWAR popcount is
    validated against an independent implementation, bit for bit.
    """
    xo = jnp.bitwise_xor(
        packed_x.astype(jnp.uint32)[:, None, :],
        packed_rows.astype(jnp.uint32)[None, :, :],
    )
    pop = jax.lax.population_count(xo).astype(jnp.int32).sum(axis=-1)
    return jnp.int32(n_in) - 2 * pop


def tiled_int8_matvec_ref(
    q: jax.Array, packed_rows: jax.Array, *, n_in: int
) -> jax.Array:
    """Oracle for the int8 x binary decode matvec — INTEGER-exact.

    q (m, K >= n_in) int8; packed_rows (r, ceil(n_in/32)) int32. Unpacks
    the rows to ±1 **int32** and contracts in the integer domain — the
    (m, r) int32 result is the exact accumulator the kernel must hit
    (the kernel's ``2*(q @ bits) - rowsum`` fold is the same integer).
    """
    t = unpack_bits(packed_rows, n_in, dtype=jnp.int32)  # (r, n_in) ±1
    return jax.lax.dot_general(
        q[:, :n_in].astype(jnp.int32), t,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32,
    )


def tiled_conv_dense_weight(
    packed: jax.Array, alpha: jax.Array, spec: TileSpec, dtype=jnp.float32
) -> jax.Array:
    """Rebuild the FULL dense OIHW weight from a conv-layout packed tile.

    Ground truth only — this is exactly the materialization the tiled conv
    kernel exists to avoid.
    """
    plan = plan_conv_tiling(spec)
    kh, kw = plan.kernel
    bank = unpack_conv_tile(packed, plan.r, plan.c_in, kh, kw, dtype=dtype)
    w = jnp.broadcast_to(bank[None], (spec.p, plan.r, plan.c_in, kh, kw))
    w = w.reshape(spec.shape)
    return (w * expand_alpha(alpha, spec).astype(dtype)).astype(dtype)


def tiled_conv_ref(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    spec: TileSpec,
    *,
    stride=(1, 1),
    padding="SAME",
) -> jax.Array:
    """Dense ground truth for ``ops.tiled_conv_infer``: materialize W_hat and
    run ``jax.lax.conv_general_dilated`` on it."""
    w = tiled_conv_dense_weight(packed, alpha, spec, dtype=jnp.float32)
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w,
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
    )


def replicate_scale_ref(u: jax.Array, alpha: jax.Array, p: int) -> jax.Array:
    """y[:, i*r:(i+1)*r] = alpha_i * u — the broadcast stage."""
    m, r = u.shape
    if alpha.shape[0] == 1:
        y = jnp.broadcast_to(u[:, None, :], (m, p, r)) * alpha.reshape(1, 1, 1)
    else:
        y = u[:, None, :] * alpha[None, :, None]
        y = jnp.broadcast_to(y, (m, p, r))
    return y.reshape(m, p * r).astype(u.dtype)

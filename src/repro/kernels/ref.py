"""Pure-jnp oracles for the TBN Pallas kernels.

These are the ground truth the kernels are validated against (allclose over
shape/dtype sweeps in tests/test_kernels_*.py) and the math the SPMD dry-run
lowers (the dry-run targets the host platform where Pallas TPU kernels
cannot compile — identical FLOPs/bytes, see DESIGN.md §7.2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import pack_bits, unpack_bits
from repro.core.tiling import TileSpec, compute_alpha, tile_vector


def tile_construct_ref(
    w2d: jax.Array, a2d: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """(p, q) master weight -> (packed tile int32 (ceil(q/32),), alpha (p,)).

    alpha here is always per-tile (Eq. 9); Eq. 7's layer alpha is its mean —
    the wrapper reduces when alpha_mode == "layer".
    """
    p, q = w2d.shape
    s = w2d.sum(axis=0)
    t = jnp.where(s > 0, 1.0, -1.0)
    src = w2d if a2d is None else a2d
    alpha = jnp.mean(jnp.abs(src), axis=1)
    return pack_bits(t), alpha.astype(jnp.float32)


def tiled_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    *,
    n_out: int,
    p: int,
) -> jax.Array:
    """Dense ground truth: y = x @ W_hat^T with W_hat fully materialized.

    x: (M, K); packed: int32 (ceil(q/32),) with q = n_out*K/p; alpha: (p,) or
    (1,). Returns (M, n_out) in float32.
    """
    m, k = x.shape
    q = n_out * k // p
    t = unpack_bits(packed, q, dtype=jnp.float32)
    b = jnp.broadcast_to(t[None, :], (p, q)).reshape(n_out, k)
    if alpha.shape[0] == 1:
        a_col = jnp.broadcast_to(alpha.reshape(1, 1), (p, q))
    else:
        a_col = jnp.broadcast_to(alpha[:, None], (p, q))
    bhat = b * a_col.reshape(n_out, k)
    return (x.astype(jnp.float32) @ bhat.T).astype(jnp.float32)


def tiled_matmul_unique_ref(
    x: jax.Array, packed: jax.Array, *, r: int
) -> jax.Array:
    """Oracle of the kernel's inner product only: u = x @ T^T (M, r)."""
    m, k = x.shape
    t = unpack_bits(packed, r * k, dtype=jnp.float32).reshape(r, k)
    return x.astype(jnp.float32) @ t.T


def replicate_scale_ref(u: jax.Array, alpha: jax.Array, p: int) -> jax.Array:
    """y[:, i*r:(i+1)*r] = alpha_i * u — the broadcast stage."""
    m, r = u.shape
    if alpha.shape[0] == 1:
        y = jnp.broadcast_to(u[:, None, :], (m, p, r)) * alpha.reshape(1, 1, 1)
    else:
        y = u[:, None, :] * alpha[None, :, None]
        y = jnp.broadcast_to(y, (m, p, r))
    return y.reshape(m, p * r).astype(u.dtype)

"""Train-step builder: loss -> grads -> clip -> (optional compressed DP
all-reduce) -> optimizer, with microbatch gradient accumulation.

The returned step is a pure function (TrainState, batch) -> (TrainState,
metrics) ready for jax.jit with sharded in/out. Remat and scan-over-layers
live inside the model; this layer adds accumulation and the update rule.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import clip_by_global_norm
from repro.optim.adamw import Optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_state(params, optimizer: Optimizer) -> TrainState:
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def build_train_step(
    loss_fn: Callable[[Any, Dict], Tuple[jax.Array, Dict]],
    optimizer: Optimizer,
    *,
    grad_accum: int = 1,
    clip_norm: Optional[float] = 1.0,
    grad_transform: Optional[Callable] = None,   # e.g. compressed DP allreduce
):
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def microbatched_grads(params, batch):
        if grad_accum <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads

        def split(x):
            b = x.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, aux), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), aux

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (gsum, loss_sum), aux = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro
        )
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        aux = jax.tree.map(lambda a: a[-1], aux)
        return loss_sum / grad_accum, aux, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, aux, grads = microbatched_grads(state.params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        gnorm = jnp.zeros(())
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
